"""Fault-tolerance primitives: failure injection, straggler detection, and
the checkpoint/restart supervisor used by the training loop.

Posture for 1000+ nodes (DESIGN.md §5): preemptions and hardware failures
are the common case, not the exception. The supervisor treats any exception
from the step function as a (possibly transient) node failure: it restores
the latest checkpoint, rebuilds device state, and resumes. The data pipeline
is stateless (batch = f(step)), so restarts replay no data and skip none.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable

log = logging.getLogger("repro.fault")


class SimulatedFailure(RuntimeError):
    pass


class FailureInjector:
    """Raises SimulatedFailure at the given step numbers (test/chaos tool)."""

    def __init__(self, fail_at_steps=(), fail_once: bool = True):
        self.fail_at = set(fail_at_steps)
        self.fail_once = fail_once
        self.fired: set[int] = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and (not self.fail_once or step not in self.fired):
            self.fired.add(step)
            raise SimulatedFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class StragglerMonitor:
    """Step-time EMA; flags steps slower than `threshold` x the EMA.

    On a real pod the flag feeds the control plane (re-shard away from the
    slow host / re-route ICI traffic); here it is surfaced in metrics and
    asserted on in tests.
    """

    threshold: float = 3.0
    ema: float | None = None
    alpha: float = 0.1
    flagged: int = 0

    def record(self, dt: float) -> bool:
        is_straggler = self.ema is not None and dt > self.threshold * self.ema
        if is_straggler:
            self.flagged += 1
            log.warning("straggler step: %.4fs vs EMA %.4fs", dt, self.ema)
        else:
            # stragglers don't poison the EMA
            self.ema = dt if self.ema is None else (1 - self.alpha) * self.ema + self.alpha * dt
        return is_straggler


class Supervisor:
    """Checkpoint/restart wrapper around a step function.

    step_fn(state, step_idx) -> (state, metrics); state must be
    checkpointable (pytree of arrays). Restores on ANY exception, up to
    max_restarts times.
    """

    def __init__(
        self,
        step_fn: Callable,
        checkpoint_manager,
        *,
        save_every: int = 50,
        max_restarts: int = 10,
        injector: FailureInjector | None = None,
        straggler: StragglerMonitor | None = None,
        async_save: bool = True,
    ):
        self.step_fn = step_fn
        self.ckpt = checkpoint_manager
        self.save_every = save_every
        self.max_restarts = max_restarts
        self.injector = injector
        self.straggler = straggler or StragglerMonitor()
        self.async_save = async_save
        self.restarts = 0
        self.metrics_log: list[dict] = []

    def run(self, state, n_steps: int, *, start_step: int = 0):
        step = start_step
        while step < n_steps:
            try:
                if self.injector is not None:
                    self.injector.maybe_fail(step)
                t0 = time.perf_counter()
                state, metrics = self.step_fn(state, step)
                dt = time.perf_counter() - t0
                is_straggler = self.straggler.record(dt)
                self.metrics_log.append(
                    dict(metrics, step=step, step_time=dt, straggler=is_straggler)
                )
                step += 1
                if step % self.save_every == 0 or step == n_steps:
                    self.ckpt.save(step, state, blocking=not self.async_save)
            except Exception as exc:  # noqa: BLE001 — any failure = node loss
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                log.warning("step %d failed (%s); restoring latest checkpoint", step, exc)
                self.ckpt.wait()
                latest = self.ckpt.latest_step()
                if latest is None:
                    # nothing saved yet: restart from the initial state
                    step = start_step
                    continue
                state, step = self.ckpt.restore(state)
        self.ckpt.wait()
        return state, step
