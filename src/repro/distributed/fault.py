"""Fault-tolerance primitives: failure injection, straggler detection, and
the checkpoint/restart supervisors used by the training loop and the PIC
drivers.

Posture for 1000+ nodes (DESIGN.md §5): preemptions and hardware failures
are the common case, not the exception. The supervisors treat any exception
from the step function as a (possibly transient) node failure: they restore
the latest checkpoint, rebuild device state, and resume. The data pipeline
is stateless (batch = f(step)), so restarts replay no data and skip none.

Two layers live here:

* the generic training-loop pieces (``FailureInjector`` / ``Supervisor``)
  kept from the original stack, and
* the PIC-aware chaos harness and window supervisor: a declarative frozen
  ``FaultSpec`` (serialized on ``SimSpec``) drives deterministic in-graph
  fault injection (NaN into a named field component / momenta, charge-scale
  weight corruption, forced migration recv-drop) or a host-side simulated
  crash, and ``run_supervised_windows`` runs either driver's windowed loop
  under the health sentinel with snapshot/rollback-and-retry on health
  halts and checkpoint-restore on hard exceptions (docs/robustness.md).

This module must stay importable without ``repro.api`` or ``repro.pic``
(both import it); anything from those packages is imported lazily inside
functions.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable

import jax.numpy as jnp

from repro.core.health import (
    HALT_INVARIANT,
    HALT_NAMES,
    HALT_NONFINITE,
    INVARIANT_NAMES,
    SimulationHealthError,
)

log = logging.getLogger("repro.fault")


class SimulatedFailure(RuntimeError):
    pass


class FailureInjector:
    """Raises SimulatedFailure at the given step numbers (test/chaos tool)."""

    def __init__(self, fail_at_steps=(), fail_once: bool = True):
        self.fail_at = set(fail_at_steps)
        self.fail_once = fail_once
        self.fired: set[int] = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and (not self.fail_once or step not in self.fired):
            self.fired.add(step)
            raise SimulatedFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class StragglerMonitor:
    """Step-time EMA; flags steps slower than `threshold` x the EMA.

    On a real pod the flag feeds the control plane (re-shard away from the
    slow host / re-route ICI traffic); here it is surfaced in metrics and
    asserted on in tests.
    """

    threshold: float = 3.0
    ema: float | None = None
    alpha: float = 0.1
    flagged: int = 0

    def record(self, dt: float) -> bool:
        is_straggler = self.ema is not None and dt > self.threshold * self.ema
        if is_straggler:
            self.flagged += 1
            log.warning("straggler step: %.4fs vs EMA %.4fs", dt, self.ema)
        else:
            # stragglers don't poison the EMA
            self.ema = dt if self.ema is None else (1 - self.alpha) * self.ema + self.alpha * dt
        return is_straggler


class Supervisor:
    """Checkpoint/restart wrapper around a step function.

    step_fn(state, step_idx) -> (state, metrics); state must be
    checkpointable (pytree of arrays). Restores on ANY exception, up to
    max_restarts times.
    """

    def __init__(
        self,
        step_fn: Callable,
        checkpoint_manager,
        *,
        save_every: int = 50,
        max_restarts: int = 10,
        injector: FailureInjector | None = None,
        straggler: StragglerMonitor | None = None,
        async_save: bool = True,
    ):
        self.step_fn = step_fn
        self.ckpt = checkpoint_manager
        self.save_every = save_every
        self.max_restarts = max_restarts
        self.injector = injector
        self.straggler = straggler or StragglerMonitor()
        self.async_save = async_save
        self.restarts = 0
        self.metrics_log: list[dict] = []

    def run(self, state, n_steps: int, *, start_step: int = 0):
        step = start_step
        while step < n_steps:
            try:
                if self.injector is not None:
                    self.injector.maybe_fail(step)
                t0 = time.perf_counter()
                state, metrics = self.step_fn(state, step)
                dt = time.perf_counter() - t0
                is_straggler = self.straggler.record(dt)
                self.metrics_log.append(
                    dict(metrics, step=step, step_time=dt, straggler=is_straggler)
                )
                step += 1
                if step % self.save_every == 0 or step == n_steps:
                    self.ckpt.save(step, state, blocking=not self.async_save)
            except Exception as exc:  # noqa: BLE001 — any failure = node loss
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                log.warning("step %d failed (%s); restoring latest checkpoint", step, exc)
                self.ckpt.wait()
                latest = self.ckpt.latest_step()
                if latest is None:
                    # nothing saved yet: restart from the initial state
                    step = start_step
                    continue
                state, step = self.ckpt.restore(state)
        self.ckpt.wait()
        return state, step


# ---------------------------------------------------------------------------
# PIC-aware declarative chaos harness
# ---------------------------------------------------------------------------

# In-graph fault kinds, encoded into a traced i32[3] vector
# [kind, step, component] so arming a fault never recompiles the window.
FAULT_NONE = 0
FAULT_NAN_FIELD = 1
FAULT_NAN_MOMENTUM = 2
FAULT_CHARGE_SCALE = 3
FAULT_RECV_DROP = 4

FIELD_COMPONENTS = ("ex", "ey", "ez", "bx", "by", "bz")

# "crash" is host-side only (raises SimulatedFailure between windows).
FAULT_KINDS = {
    "nan_field": FAULT_NAN_FIELD,
    "nan_momentum": FAULT_NAN_MOMENTUM,
    "charge_scale": FAULT_CHARGE_SCALE,
    "recv_drop": FAULT_RECV_DROP,
    "crash": FAULT_NONE,
}

GRAPH_FAULT_KINDS = frozenset(k for k in FAULT_KINDS if k != "crash")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Declarative fault to inject — serialized on ``SimSpec`` so every chaos
    scenario is reproducible from a spec file.

    ``kind``: one of ``nan_field`` (poison ``component`` before the step),
    ``nan_momentum`` (poison particle momenta), ``charge_scale`` (double the
    macro-particle weights, violating charge conservation), ``recv_drop``
    (force the distributed migration recv-drop halt), ``crash`` (raise
    ``SimulatedFailure`` on the host before the window containing ``step``).

    ``step``: the absolute step counter at which the fault fires; in-graph
    faults corrupt the *input* of step ``step + 1``, so that is the step the
    sentinel reports. ``count``: how many times the fault fires; ``0`` means
    persistent (fires on every opportunity — used to test ladder exhaustion).
    """

    kind: str = "nan_field"
    step: int = 0
    component: str = "ez"
    count: int = 1

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; expected one of {sorted(FAULT_KINDS)}")
        if self.component not in FIELD_COMPONENTS:
            raise ValueError(f"unknown field component {self.component!r}")
        if self.step < 0 or self.count < 0:
            raise ValueError("FaultSpec step and count must be >= 0")

    @staticmethod
    def from_dict(d: dict) -> "FaultSpec":
        names = {f.name for f in dataclasses.fields(FaultSpec)}
        unknown = set(d) - names
        if unknown:
            raise ValueError(f"FaultSpec has unknown keys {sorted(unknown)}")
        return FaultSpec(**d)


def no_fault_vec():
    """Fault vector that never fires (step -1 matches no counter)."""
    return jnp.array([FAULT_NONE, -1, 0], jnp.int32)


def inject_fields(fields, step_count, fault_vec):
    """Poison one field component with NaN when the fault fires.

    ``fields``: tuple of the six field arrays in ``FIELD_COMPONENTS`` order;
    ``step_count``: traced i32 absolute step counter at window position i.
    Pure masked select — a non-firing vector returns the inputs unchanged.
    """
    fire = (fault_vec[0] == FAULT_NAN_FIELD) & (step_count == fault_vec[1])
    out = []
    for i, f in enumerate(fields):
        hit = fire & (fault_vec[2] == jnp.int32(i))
        out.append(jnp.where(hit, jnp.full_like(f, jnp.nan), f))
    return tuple(out)


def inject_momenta(u, step_count, fault_vec):
    """Poison particle momenta with NaN when a nan_momentum fault fires."""
    fire = (fault_vec[0] == FAULT_NAN_MOMENTUM) & (step_count == fault_vec[1])
    return jnp.where(fire, jnp.full_like(u, jnp.nan), u)


def inject_weights(w, step_count, fault_vec):
    """Double macro-particle weights when a charge_scale fault fires."""
    fire = (fault_vec[0] == FAULT_CHARGE_SCALE) & (step_count == fault_vec[1])
    return jnp.where(fire, w * jnp.asarray(2.0, w.dtype), w)


def injected_recv_drop(step_count, fault_vec):
    """i32 1 when a recv_drop fault fires at this step, else 0."""
    fire = (fault_vec[0] == FAULT_RECV_DROP) & (step_count == fault_vec[1])
    return fire.astype(jnp.int32)


class PICFaultInjector:
    """Host-side driver of a ``FaultSpec``: arms the in-graph fault vector
    for windows that cover ``spec.step``, raises simulated crashes, and
    retires the fault after it has fired ``spec.count`` times so retried /
    replayed windows run clean."""

    def __init__(self, spec: FaultSpec):
        self.spec = spec
        self.remaining = spec.count if spec.count > 0 else None  # None = persistent
        self.fired = 0

    def _armed(self) -> bool:
        return self.remaining is None or self.remaining > 0

    def _consume(self) -> None:
        self.fired += 1
        if self.remaining is not None:
            self.remaining -= 1

    def window_vec(self, host_step: int, k: int):
        """Fault vector for a window of k steps starting at ``host_step``,
        or None when no in-graph fault is armed for it."""
        if self.spec.kind not in GRAPH_FAULT_KINDS or not self._armed():
            return None
        if not host_step <= self.spec.step < host_step + k:
            return None
        comp = FIELD_COMPONENTS.index(self.spec.component)
        return jnp.array([FAULT_KINDS[self.spec.kind], self.spec.step, comp], jnp.int32)

    def maybe_crash(self, host_step: int, k: int) -> None:
        if self.spec.kind != "crash" or not self._armed():
            return
        if host_step <= self.spec.step < host_step + k:
            self._consume()
            raise SimulatedFailure(
                f"injected crash before window at step {host_step}"
            )

    def note_halt(self, code: int, halt_step: int) -> None:
        """Record that a window halt consumed one firing of the armed fault.
        In-graph faults corrupt the input of step ``spec.step + 1``, so only
        a halt at exactly that step is attributed to the injector."""
        if self.spec.kind in GRAPH_FAULT_KINDS and self._armed() and halt_step == self.spec.step + 1:
            self._consume()


# ---------------------------------------------------------------------------
# Shared windowed-run supervisor (both PIC drivers)
# ---------------------------------------------------------------------------


def run_supervised_windows(sim, n_steps: int, diagnostics_every: int,
                           window: int, *, autosave_every: int = 0,
                           autosave_path: str = "") -> None:
    """Run ``n_steps`` of a windowed PIC driver under fault supervision.

    ``sim`` is either driver (``pic.simulation.Simulation`` or
    ``pic.dist_simulation.DistSimulation``); both expose the same hook set:
    ``_take_snapshot``/``_restore_snapshot`` (device-resident window-start
    carry), ``_enter_window`` (launch one compiled window, return the host
    bundle), ``_consume_bundle`` (commit a successful window), ``_handle_halt``
    (grow-and-continue for the overflow/migration halt family),
    ``_remedy_sort`` and ``_demote_backend`` (remediation ladder rungs), plus
    the ``halts``/``retries``/``restarts``/``discarded_steps`` counters.

    Recovery paths:

    * health halt (``HALT_NONFINITE``/``HALT_INVARIANT``): restore the
      window-start snapshot and retry under the escalating ladder — halve
      the window, then force a global sort, then demote the kernel backend, then
      abort with ``SimulationHealthError`` naming the halt code, step, and
      offending invariant;
    * capacity halts (overflow / migration family): delegate to the driver's
      grow-and-continue handler exactly as before;
    * hard Python/XLA exception: restore the latest on-disk checkpoint
      (``autosave_every`` wires a ``SimCheckpointer`` in automatically) and
      resume, up to ``max_restarts`` times.
    """
    health = sim._health
    inj = sim.fault_injector
    max_retries = health.max_retries if health is not None else 3
    max_restarts = health.max_restarts if health is not None else 3

    ckpt = None
    if autosave_every:
        from repro.api.facade import SimCheckpointer

        ckpt = SimCheckpointer(sim, autosave_path, every=autosave_every)
        ckpt.maybe_save(sim._host_step, force=True)

    target = sim._host_step + n_steps
    retry_target = 0  # nonzero: ladder level >= 1 capped the window length
    while True:
        try:
            while sim._host_step < target:
                k = min(window, target - sim._host_step)
                if retry_target:
                    k = min(k, retry_target)
                if inj is not None:
                    inj.maybe_crash(sim._host_step, k)
                fault_vec = inj.window_vec(sim._host_step, k) if inj is not None else None
                snap = sim._take_snapshot() if health is not None else None
                host = sim._enter_window(k, window, diagnostics_every, fault_vec)
                code = int(host.get("halt_code", 0))

                if code in (HALT_NONFINITE, HALT_INVARIANT):
                    sim._restore_snapshot(snap)
                    name = HALT_NAMES[code]
                    sim.halts[name] = sim.halts.get(name, 0) + 1
                    if inj is not None:
                        inj.note_halt(code, int(host.get("halt_step", -1)))
                    sim.retries += 1
                    sim._remedy_level += 1
                    level = sim._remedy_level
                    exhausted = level > max_retries
                    if not exhausted and level >= 3:
                        # last rung: demote the kernel backend one step down
                        # the dispatcher's priority ladder; exhausted when
                        # already on the most conservative backend
                        exhausted = not sim._demote_backend()
                    if exhausted:
                        raise SimulationHealthError(
                            halt=name,
                            step=int(host.get("halt_step", -1)),
                            invariant=INVARIANT_NAMES[int(host.get("halt_inv", 0))],
                            measured=float(host.get("halt_measured", float("nan"))),
                            reference=float(host.get("halt_reference", float("nan"))),
                            retries=sim.retries,
                        )
                    if level == 1:
                        retry_target = max(1, k // 2)
                    elif level == 2:
                        sim._remedy_sort()
                    log.warning(
                        "health halt %s at step %s: rollback, remediation level %d",
                        name, host.get("halt_step"), level,
                    )
                    continue

                n_done = sim._consume_bundle(host, diagnostics_every)
                sim.discarded_steps += int(host.get("n_discarded", 0))
                sim._remedy_level = 0
                retry_target = 0
                if code:
                    name = HALT_NAMES[code]
                    sim.halts[name] = sim.halts.get(name, 0) + 1
                    if inj is not None:
                        inj.note_halt(code, int(host.get("halt_step", -1)))
                    sim._handle_halt(code, host)
                elif n_done < k:
                    raise RuntimeError("windowed driver made no progress without a halt")
                if ckpt is not None:
                    ckpt.maybe_save(sim._host_step)
            break
        except SimulationHealthError:
            raise
        except Exception as exc:  # noqa: BLE001 — any failure = node loss
            if ckpt is None:
                raise
            sim.restarts += 1
            if sim.restarts > max_restarts:
                raise
            restarts = sim.restarts
            log.warning("window at step %d failed (%s); restoring latest checkpoint",
                        sim._host_step, exc)
            from repro.api.facade import restore_simulation

            restore_simulation(sim, ckpt.latest_path())
            # the checkpoint predates the crash: keep the live restart count
            sim.restarts = restarts
            sim._remedy_level = 0
            retry_target = 0
    if ckpt is not None:
        ckpt.maybe_save(sim._host_step, force=True)
