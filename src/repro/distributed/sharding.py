"""Logical-axis sharding: models annotate tensors with *logical* axis names;
launchers install a rules table mapping logical names to mesh axes.

Without an installed rules table (unit tests, single device) every
annotation is a no-op, so model code is identical on 1 chip and 512.

Logical axes used across the stack:
  batch       global batch                    -> ('pod','data') / ('data',)
  seq         sequence (activations)          -> 'model' (sequence parallel)
  kv_seq      KV-cache sequence               -> shape-strategy dependent
  heads       attention heads                 -> 'model'
  embed       residual stream features        -> usually None (replicated)
  mlp         FFN hidden                      -> 'model'
  experts     MoE expert dim                  -> 'model' (EP)
  vocab       vocabulary                      -> 'model'
  fsdp        parameter sharding dim          -> 'data' (ZeRO-3)
  stack       scan-stacked layer dim          -> None
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


# ------------------------------------------------------------------
# load-aware 2-D domain-decomposition planning (PIC rebalance)
# ------------------------------------------------------------------

def valid_mesh_splits(n_devices: int, global_shape, order: int) -> list[tuple[int, int]]:
    """Every (sx, sy) factorization of `n_devices` whose implied local block
    divides the global grid and keeps each decomposed extent at least the
    deposition guard width (`halo` slabs must not wrap into the neighbor's
    neighbor — same constraint `pic.distributed.validate_shard_guard`
    enforces on the configured split)."""
    from repro.core.shape_functions import max_guard

    g = max_guard(order)
    nx, ny, nz = global_shape
    out = []
    for sx in range(1, n_devices + 1):
        if n_devices % sx:
            continue
        sy = n_devices // sx
        if nx % sx or ny % sy:
            continue
        if min(nx // sx, ny // sy, nz) < g:
            continue
        out.append((sx, sy))
    return out


def plan_balanced_split(n_devices: int, global_shape, order: int, pos, alive):
    """Pick the (sx, sy) decomposition minimizing the max per-shard alive
    particle count — the load-aware repartitioning step behind
    ``HALT_IMBALANCE``. `pos` (N, 3) global-frame positions, `alive` (N,)
    mask (host arrays). Ties break toward fewer shard-boundary columns along
    x (less x-migration traffic) and then toward the squarer split.

    Returns ``(sx, sy, peak)`` with `peak` the winning split's max shard
    count; raises if no factorization is valid."""
    import numpy as np

    splits = valid_mesh_splits(n_devices, global_shape, order)
    if not splits:
        raise ValueError(
            f"no valid (sx, sy) split of {n_devices} devices for grid "
            f"{tuple(global_shape)} at order {order}"
        )
    pos = np.asarray(pos)
    alive = np.asarray(alive)
    x = pos[alive, 0]
    y = pos[alive, 1]
    best = None
    for sx, sy in splits:
        ix = np.clip((x // (global_shape[0] // sx)).astype(int), 0, sx - 1)
        iy = np.clip((y // (global_shape[1] // sy)).astype(int), 0, sy - 1)
        peak = int(np.bincount(ix * sy + iy, minlength=sx * sy).max()) if x.size else 0
        key = (peak, sx, abs(sx - sy))
        if best is None or key < best[0]:
            best = (key, (sx, sy, peak))
    return best[1]


class Rules:
    """Mapping logical axis name -> mesh axis (str | tuple | None)."""

    def __init__(self, table: dict, mesh=None):
        self.table = dict(table)
        self.mesh = mesh

    def spec(self, axes: tuple) -> P:
        out = []
        for ax in axes:
            m = self.table.get(ax) if ax is not None else None
            out.append(m)
        return P(*out)


def current_rules() -> Rules | None:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Rules | None):
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def logical_spec(axes: tuple) -> P | None:
    r = current_rules()
    return r.spec(axes) if r is not None else None


def constrain(x, *axes):
    """with_sharding_constraint by logical axes; no-op without rules."""
    r = current_rules()
    if r is None:
        return x
    return jax.lax.with_sharding_constraint(x, r.spec(axes))


def tree_specs(logical_tree, rules: Rules):
    """Map a pytree of logical-axis tuples to a pytree of PartitionSpecs."""
    return jax.tree.map(
        lambda axes: rules.spec(axes),
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
    )


# ------------------------------------------------------------------
# standard rule tables per run mode (see DESIGN.md §5)
# ------------------------------------------------------------------

def train_rules(multi_pod: bool, *, expert_parallel: bool = True) -> dict:
    """expert_parallel: EP shards MoE experts over 'model' (needs
    n_experts % model_axis == 0); otherwise TP shards the expert FFN width
    (mixtral: 8 experts < 16-way model axis)."""
    batch = ("pod", "data") if multi_pod else ("data",)
    return {
        "batch": batch,
        "seq": "model",        # sequence-parallel residual stream
        "kv_seq": None,
        "heads": "model",
        "kv_heads": "model",
        "embed": None,
        "mlp": "model",
        "experts": "model" if expert_parallel else None,
        "expert_mlp": None if expert_parallel else "model",
        "vocab": "model",
        "fsdp": batch,         # ZeRO param/optimizer sharding
        "stack": None,
    }


def rules_for(cfg, *, mode: str, multi_pod: bool, data_axis: int = 16, model_axis: int = 16, shard_batch: bool = True) -> dict:
    """Arch-aware rule table: every logical axis falls back to replication
    when the corresponding tensor dimension doesn't divide the mesh axis
    (whisper's 6 heads, starcoder2-7b's 36 heads, mixtral's 8 experts, ...).

    mode: "train" | "decode". For decode, if kv heads can't shard over
    'model' the KV-cache *sequence* is sharded there instead
    (flash-decode-style partial-softmax reduction, handled by XLA).
    """
    batch = ("pod", "data") if multi_pod else ("data",)
    div = lambda n, m: (n % m == 0) and n >= m

    # uneven sharding (GSPMD pads) is fine when the dim exceeds the axis:
    # starcoder2-7b's 36 heads pad to 48 (33% attn overhead << replication)
    heads = "model" if cfg.n_heads >= model_axis else None
    kv_heads = "model" if div(cfg.n_kv_heads, model_axis) else None
    vocab = "model"  # always worth sharding; pad <= 1 row per shard
    mlp = "model"
    ep = cfg.moe is not None and div(cfg.moe.n_experts, model_axis)

    table = {
        "batch": batch if shard_batch else None,
        "seq": "model" if mode == "train" else None,
        "kv_seq": None,
        "heads": heads,
        "kv_heads": kv_heads,
        "embed": None,
        "mlp": mlp,
        "experts": "model" if ep else None,
        "expert_mlp": None if (ep or cfg.moe is None) else "model",
        "vocab": vocab,
        "fsdp": batch,
        "stack": None,
    }
    if mode == "decode":
        if kv_heads is None:
            table["kv_seq"] = "model"
        if not shard_batch:
            # batch=1 long-context decode: shard KV sequence over everything
            table["kv_seq"] = batch + ("model",) if kv_heads is None else batch
    if cfg.name.startswith("whisper"):
        # tiny model: sequence parallelism not worth it / 1500-frame encoder
        table["seq"] = None
    return table


def decode_rules(multi_pod: bool, *, shard_batch: bool = True, expert_parallel: bool = True) -> dict:
    batch = ("pod", "data") if multi_pod else ("data",)
    return {
        "batch": batch if shard_batch else None,
        "seq": None,
        # batch=1 long-context decode shards the KV sequence instead
        "kv_seq": None if shard_batch else batch,
        "heads": "model",
        "kv_heads": "model",
        "embed": None,
        "mlp": "model",
        "experts": "model" if expert_parallel else None,
        "expert_mlp": None if expert_parallel else "model",
        "vocab": "model",
        "fsdp": batch,
        "stack": None,
    }
