"""GPipe-style pipeline parallelism over a 'pipe' mesh axis (shard_map +
collective_permute), demonstrating the PP capability orthogonally to the
production (data, model) mesh.

Schedule: n_micro microbatches flow through n_stages stages in
n_micro + n_stages - 1 ticks; each tick every stage processes one resident
microbatch and ppermutes its activation to the next stage. Bubble fraction
is (S-1)/(M+S-1), the standard GPipe bound — the test asserts numerical
equality with the sequential composition of the stages.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map_compat


def pipeline_forward(stage_params, microbatches, stage_fn, *, mesh, axis_name: str = "pipe"):
    """Run microbatches through staged layers.

    stage_params: pytree with leading dim = n_stages (sharded over 'pipe').
    microbatches: (n_micro, mb, ...) replicated input.
    stage_fn(params_slice, x) -> y, same shape as x.
    Returns (n_micro, mb, ...) outputs of the final stage.
    """
    n_stages = mesh.shape[axis_name]
    n_micro = microbatches.shape[0]
    ticks = n_micro + n_stages - 1

    def body(params, mb):
        # params: stage-local slice (leading dim 1); mb: full (replicated)
        my = lax.axis_index(axis_name)
        p_local = jax.tree.map(lambda a: a[0], params)
        fwd_perm = [(i, i + 1) for i in range(n_stages - 1)]

        def tick(carry, t):
            incoming, outputs = carry
            # stage 0 ingests microbatch t (others use the permuted input)
            feed = jnp.where(t < n_micro, 1, 0)
            mb_t = mb[jnp.minimum(t, n_micro - 1)]
            x = jnp.where((my == 0) & (feed == 1), mb_t, incoming)
            y = stage_fn(p_local, x)
            # last stage records its result at slot t - (n_stages - 1)
            out_slot = t - (n_stages - 1)
            write = (my == n_stages - 1) & (out_slot >= 0)
            outputs = lax.cond(
                write,
                lambda o: lax.dynamic_update_index_in_dim(o, y, jnp.maximum(out_slot, 0), 0),
                lambda o: o,
                outputs,
            )
            nxt = lax.ppermute(y, axis_name, fwd_perm)
            return (nxt, outputs), None

        init = (jnp.zeros_like(mb[0]), jnp.zeros_like(mb))
        (_, outputs), _ = lax.scan(tick, init, jnp.arange(ticks))
        # only the last stage holds real outputs; broadcast via masked psum
        # (ppermute is a strict permutation — no one-to-many edges)
        outputs = jnp.where(my == n_stages - 1, outputs, jnp.zeros_like(outputs))
        return lax.psum(outputs, axis_name)

    spec_params = jax.tree.map(lambda _: P(axis_name), stage_params)
    fn = shard_map_compat(
        body, mesh=mesh, in_specs=(spec_params, P()), out_specs=P(), check_vma=False
    )
    return fn(stage_params, microbatches)
