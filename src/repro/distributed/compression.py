"""Error-feedback int8 gradient compression for the data-parallel all-reduce.

Scheme (1-bit-Adam / EF-SGD family):
  1. g' = g + residual                  (error feedback)
  2. scale = pmax(|g'|) / 127           (shared scale across the DP axis)
  3. q = round(g'/scale) in int8        (4x less ICI traffic than fp32)
  4. G = psum(q) * scale / n_shards     (integer all-reduce)
  5. residual' = g' - dequant(q)        (compression error carried forward)

Exposed as `compressed_psum_grads` for use inside a shard_map'd DP train
step. With compression disabled it degenerates to a plain psum (the test
compares convergence of both paths).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.compat import axis_size_compat


def zeros_like_residual(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _compress_one(g, r, axis_name):
    g32 = g.astype(jnp.float32) + r
    amax = lax.pmax(jnp.max(jnp.abs(g32)), axis_name)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    new_r = g32 - deq
    n = axis_size_compat(axis_name)
    summed = lax.psum(q.astype(jnp.int32), axis_name).astype(jnp.float32) * scale / n
    return summed.astype(g.dtype), new_r


def compressed_psum_grads(grads, residuals, axis_name: str):
    """Returns (mean-reduced grads, new residuals)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    out = [_compress_one(g, r, axis_name) for g, r in zip(flat_g, flat_r)]
    return treedef.unflatten([o[0] for o in out]), treedef.unflatten([o[1] for o in out])


def exact_pmean_grads(grads, axis_name: str):
    return jax.tree.map(lambda g: lax.pmean(g, axis_name), grads)
