"""Quantized payload compression for the distributed collectives.

Two consumers share the fixed-point quantize–dequantize core here:

1. **Error-feedback int8 gradient compression** for the data-parallel
   all-reduce (1-bit-Adam / EF-SGD family):

     1. g' = g + residual                  (error feedback)
     2. scale = pmax(|g'|) / 127           (shared scale across the DP axis)
     3. q = round(g'/scale) in int8        (4x less ICI traffic than fp32)
     4. G = psum(q) * scale / n_shards     (integer all-reduce)
     5. residual' = g' - dequant(q)        (compression error carried forward)

   Exposed as `compressed_psum_grads` for use inside a shard_map'd DP train
   step. With compression disabled it degenerates to a plain psum (the test
   compares convergence of both paths).

2. **Compressed migration payloads** for the PIC particle exchange
   (`pic.distributed.migrate_axis` with ``comm.compress_migration``):
   positions are shard-relative after the migration coordinate shift, so
   they quantize to fixed-point uint16 over the local block extent (plus a
   ±`POS_MARGIN`-cell headroom band: a particle leaving along x may still
   be up to one CFL-bounded cell out of range along y, and clipping that
   coordinate into range would silently cancel its next migration).
   Momenta round-trip through bfloat16; weights stay exact float32 so the
   total charge is conserved exactly. Documented tolerance per position
   component: ``(extent + 2*POS_MARGIN) / 2**16`` grid cells (the uint16
   step), i.e. < 1.1e-3 cells for local extents up to 64.

   Payload accounting (per buffered particle row, the `BENCH_comm` bytes):
   exact 28 B (3x f32 pos + 3x f32 u + f32 w); compressed 16 B
   (3x uint16 pos + 3x bf16 u + f32 w).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.compat import axis_size_compat

# Out-of-range headroom for position quantization, in grid cells: CFL bounds
# a particle's per-step motion below one cell, so any coordinate of a
# migrating particle lies in [-POS_MARGIN, extent + POS_MARGIN).
POS_MARGIN = 2.0

# Payload bytes per buffered migration row (pos + u + w), both modes.
MIG_ROW_BYTES_EXACT = 3 * 4 + 3 * 4 + 4
MIG_ROW_BYTES_COMPRESSED = 3 * 2 + 3 * 2 + 4


# ---------------------------------------------------------------------------
# shared fixed-point core
# ---------------------------------------------------------------------------

def quantize_fixed(x, scale, *, qmin: int, qmax: int, dtype, zero=0.0):
    """x -> round((x - zero)/scale) clipped into [qmin, qmax] as `dtype`.

    `scale`/`zero` may be scalars or broadcastable arrays (per-dim position
    scales). The reconstruction `dequantize_fixed` is exact to scale/2."""
    q = jnp.round((x - zero) / scale)
    return jnp.clip(q, qmin, qmax).astype(dtype)


def dequantize_fixed(q, scale, *, zero=0.0, dtype=jnp.float32):
    return q.astype(dtype) * scale + zero


# ---------------------------------------------------------------------------
# error-feedback int8 gradient all-reduce
# ---------------------------------------------------------------------------

def zeros_like_residual(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _compress_one(g, r, axis_name):
    g32 = g.astype(jnp.float32) + r
    amax = lax.pmax(jnp.max(jnp.abs(g32)), axis_name)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = quantize_fixed(g32, scale, qmin=-127, qmax=127, dtype=jnp.int8)
    new_r = g32 - dequantize_fixed(q, scale)
    n = axis_size_compat(axis_name)
    summed = lax.psum(q.astype(jnp.int32), axis_name).astype(jnp.float32) * scale / n
    return summed.astype(g.dtype), new_r


def compressed_psum_grads(grads, residuals, axis_name: str):
    """Returns (mean-reduced grads, new residuals)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    out = [_compress_one(g, r, axis_name) for g, r in zip(flat_g, flat_r)]
    return treedef.unflatten([o[0] for o in out]), treedef.unflatten([o[1] for o in out])


def exact_pmean_grads(grads, axis_name: str):
    return jax.tree.map(lambda g: lax.pmean(g, axis_name), grads)


# ---------------------------------------------------------------------------
# migration payload packing (pic.distributed.migrate_axis)
# ---------------------------------------------------------------------------

def _pos_scales(local_shape, dtype=jnp.float32):
    """Per-dim (scale, zero) mapping [-POS_MARGIN, ext + POS_MARGIN) onto
    the uint16 range. Static given the (static) local grid shape."""
    ext = jnp.asarray(local_shape, dtype)
    scale = (ext + 2.0 * POS_MARGIN) / 65536.0
    zero = jnp.full_like(ext, -POS_MARGIN)
    return scale, zero


def pack_positions(pos, local_shape):
    """(cap, 3) shard-relative positions -> uint16 fixed point. Dequantized
    values stay strictly below ext + POS_MARGIN (qmax maps below the range
    top), so out-of-range coordinates survive the round trip and still
    trigger their next migration."""
    scale, zero = _pos_scales(local_shape, pos.dtype)
    return quantize_fixed(pos, scale, zero=zero, qmin=0, qmax=65535, dtype=jnp.uint16)


def unpack_positions(q, local_shape, dtype=jnp.float32):
    scale, zero = _pos_scales(local_shape, dtype)
    return dequantize_fixed(q, scale, zero=zero, dtype=dtype)


def pack_momenta(u):
    return u.astype(jnp.bfloat16)


def unpack_momenta(q, dtype=jnp.float32):
    return q.astype(dtype)
